"""The paper's own index configurations (§3.3, §4).

* ``ivfflat_sift1m``  — SIFT1M-scale: dim 128, 4000 IVF lists (paper §4.3
  mentions "cluster number of ivf is 4000"), T_m = 1024 (deployment value).
* ``ivfpq_dssm40m``   — the industrial DSSM corpus: dim 64, PQ M=16.

The benchmark harness scales ``n`` down (CPU container) while keeping every
ratio (lists per vector, block fill, nprobe) — see benchmarks/.
"""

from __future__ import annotations

import dataclasses

from repro.core.ivf import IVFIndexConfig


def ivfflat_sift1m(scale: float = 1.0) -> IVFIndexConfig:
    n = int(1_000_000 * scale)
    return IVFIndexConfig(
        n_clusters=max(16, int(4000 * scale)),
        dim=128,
        block_size=1024 if scale >= 0.25 else 64,
        max_chain=64,
        capacity_vectors=2 * n,
        nprobe=32,
        k=10,
        rearrange_threshold=10_000,
    )


def ivfpq_dssm40m(scale: float = 1.0) -> IVFIndexConfig:
    n = int(40_000_000 * scale)
    return IVFIndexConfig(
        n_clusters=max(16, int(4000 * scale * 40)),
        dim=64,
        block_size=1024 if scale >= 0.01 else 64,
        max_chain=64,
        capacity_vectors=2 * n,
        payload="pq",
        pq_m=16,
        nprobe=32,
        k=10,
        rearrange_threshold=10_000,
    )
