"""wide-deep [arXiv:1606.07792]: wide linear ∥ deep MLP, 40 sparse fields."""

import dataclasses

from repro.configs.base import ArchSpec, RECSYS_SHAPES, register
from repro.models.recsys.models import RecConfig

FULL = RecConfig(
    name="wide-deep",
    kind="wide_deep",
    n_dense=0,
    vocab_sizes=(100_000,) * 40,
    embed_dim=32,
    mlp_sizes=(1024, 512, 256),
)

SMOKE = dataclasses.replace(
    FULL, vocab_sizes=(64,) * 8, embed_dim=8, mlp_sizes=(32, 16),
)

register(
    ArchSpec(
        arch_id="wide-deep",
        family="recsys",
        config=FULL,
        smoke_config=SMOKE,
        shapes=dict(RECSYS_SHAPES),
        source="arXiv:1606.07792 (paper tier)",
        notes="wide tower = dim-1 embeddings (linear over one-hots).",
    )
)
