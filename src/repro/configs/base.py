"""Architecture registry: every assigned arch is a selectable config.

``get_arch(arch_id)`` resolves the dashed public id (``--arch llama3-8b``)
to an ``ArchSpec`` bundling the full-size config (dry-run only — exercised
via ShapeDtypeStruct, never allocated), the reduced smoke config, and the
per-arch input-shape set.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

# -------------------------------------------------------- shape catalogue --

LM_SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    # long_500k requires sub-quadratic attention; every assigned LM arch is
    # pure full-attention (GQA), so the cell is skipped per the assignment
    # rule — recorded in DESIGN.md §6.
}

GNN_SHAPES = {
    "full_graph_sm": dict(
        kind="gnn_full", n_nodes=2708, n_edges=10556, d_feat=1433
    ),
    "minibatch_lg": dict(
        kind="gnn_sampled", n_nodes=232_965, n_edges=114_615_892,
        batch_nodes=1024, fanouts=(15, 10), d_feat=602,
        # padded block sizes consumed by the device step:
        max_nodes=170_000, max_edges=170_000,
    ),
    "ogb_products": dict(
        kind="gnn_full", n_nodes=2_449_029, n_edges=61_859_140, d_feat=100
    ),
    "molecule": dict(
        kind="gnn_batched", n_nodes=30, n_edges=64, batch=128, d_feat=16
    ),
}

RECSYS_SHAPES = {
    "train_batch": dict(kind="rec_train", batch=65536),
    "serve_p99": dict(kind="rec_serve", batch=512),
    "serve_bulk": dict(kind="rec_serve", batch=262_144),
    "retrieval_cand": dict(kind="rec_retrieval", batch=1, n_candidates=1_000_000),
}


@dataclasses.dataclass
class ArchSpec:
    arch_id: str
    family: str  # "lm" | "gnn" | "recsys"
    config: Any  # full-size config (dry-run only)
    smoke_config: Any  # reduced config (CPU smoke tests)
    shapes: dict
    source: str = ""  # public citation from the assignment
    notes: str = ""


_REGISTRY: dict[str, ArchSpec] = {}


def register(spec: ArchSpec) -> ArchSpec:
    _REGISTRY[spec.arch_id] = spec
    return spec


def get_arch(arch_id: str) -> ArchSpec:
    _ensure_loaded()
    if arch_id not in _REGISTRY:
        raise KeyError(
            f"unknown arch {arch_id!r}; have {sorted(_REGISTRY)}"
        )
    return _REGISTRY[arch_id]


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded():
    if _REGISTRY:
        return
    from repro.configs import (  # noqa: F401
        dcn_v2,
        dien,
        dlrm_mlperf,
        equiformer_v2,
        kimi_k2_1t_a32b,
        llama3_8b,
        llama4_maverick_400b_a17b,
        qwen1_5_110b,
        qwen3_1_7b,
        wide_deep,
    )
