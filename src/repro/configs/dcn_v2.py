"""dcn-v2 [arXiv:2008.13535]: cross network v2 ∥ deep MLP (Criteo)."""

import dataclasses

from repro.configs.base import ArchSpec, RECSYS_SHAPES, register
from repro.models.recsys.models import RecConfig

# Criteo-Kaggle-scale hashed vocabularies (paper hashes to ~1e6 per field)
FULL = RecConfig(
    name="dcn-v2",
    kind="dcn_v2",
    n_dense=13,
    vocab_sizes=(1_000_000,) * 26,
    embed_dim=16,
    mlp_sizes=(1024, 1024, 512),
    n_cross_layers=3,
)

SMOKE = dataclasses.replace(
    FULL, vocab_sizes=(64,) * 26, embed_dim=8, mlp_sizes=(32, 16),
    n_cross_layers=2,
)

register(
    ArchSpec(
        arch_id="dcn-v2",
        family="recsys",
        config=FULL,
        smoke_config=SMOKE,
        shapes=dict(RECSYS_SHAPES),
        source="arXiv:2008.13535 (paper tier)",
        notes="hashed 1e6-row tables (paper's Criteo preprocessing).",
    )
)
