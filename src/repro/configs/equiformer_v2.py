"""equiformer-v2 [arXiv:2306.12059]: SO(2)-eSCN equivariant graph attention.

Assignment config: n_layers=12 d_hidden=128 l_max=6 m_max=2 n_heads=8.
The four shapes span Cora-size full-batch, Reddit-size sampled minibatch,
ogb_products full-batch-large, and batched small molecules.

The paper's ANNS technique is inapplicable to this family (static molecular
graphs, no online vector corpus) — DESIGN.md §6 / §Arch-applicability.
"""

import dataclasses

from repro.configs.base import ArchSpec, GNN_SHAPES, register
from repro.models.gnn.equiformer_v2 import EquiformerConfig

FULL = EquiformerConfig(
    name="equiformer-v2",
    n_layers=12,
    channels=128,
    l_max=6,
    m_max=2,
    n_heads=8,
    d_feat_in=128,  # overridden per shape (d_feat differs per dataset)
    n_radial=8,
    edge_chunk=262_144,
    readout="node",
    n_out=64,
)

SMOKE = dataclasses.replace(
    FULL, n_layers=2, channels=16, l_max=2, m_max=1, n_heads=4,
    d_feat_in=8, edge_chunk=64, n_out=4,
)

register(
    ArchSpec(
        arch_id="equiformer-v2",
        family="gnn",
        config=FULL,
        smoke_config=SMOKE,
        shapes=dict(GNN_SHAPES),
        source="arXiv:2306.12059 (unverified tier)",
        notes=(
            "message passing via segment_sum over edge chunks; 3D positions "
            "synthesised for citation/product graphs; paper ANNS technique "
            "inapplicable (DESIGN.md §6)."
        ),
    )
)
