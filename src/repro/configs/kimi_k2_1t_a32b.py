"""kimi-k2-1t-a32b [arXiv:2501.kimi2]: trillion-param MoE, 384e top-8.

Assignment config: 61L d_model=7168 64H (GQA kv=8) d_ff=2048 vocab=163840,
MoE 384 experts top-8.  All layers are MoE in this build (the released model
keeps layer 0 dense; uniform layers keep the scan homogeneous — noted).
Optimizer default for this scale is Adafactor (DESIGN.md §7).
"""

import dataclasses

import jax.numpy as jnp

from repro.configs.base import ArchSpec, LM_SHAPES, register
from repro.models.transformer import LMConfig

FULL = LMConfig(
    name="kimi-k2-1t-a32b",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_head=112,
    d_ff=0,
    vocab=163840,
    moe=True,
    n_experts=384,
    top_k=8,
    d_ff_expert=2048,
    capacity_factor=1.25,
    attn_chunk=512,
    dtype=jnp.bfloat16,
)

SMOKE = dataclasses.replace(
    FULL, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    n_experts=8, top_k=2, d_ff_expert=32, vocab=512, attn_chunk=16,
    dtype=jnp.float32, remat=False,
)

register(
    ArchSpec(
        arch_id="kimi-k2-1t-a32b",
        family="lm",
        config=FULL,
        smoke_config=SMOKE,
        shapes=dict(LM_SHAPES),
        source="arXiv:2501.kimi2 paper-table (unverified tier)",
        notes=(
            "~1.03e12 total params; uniform MoE layers; adafactor default; "
            "long_500k skipped (full attention)."
        ),
    )
)
