"""llama3-8b [arXiv:2407.21783]: dense GQA decoder, 128k vocabulary."""

import dataclasses

import jax.numpy as jnp

from repro.configs.base import ArchSpec, LM_SHAPES, register
from repro.models.transformer import LMConfig

FULL = LMConfig(
    name="llama3-8b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab=128256,
    rope_theta=500_000.0,
    attn_chunk=512,
    dtype=jnp.bfloat16,
)

SMOKE = dataclasses.replace(
    FULL, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=512, attn_chunk=16, dtype=jnp.float32, remat=False,
)

register(
    ArchSpec(
        arch_id="llama3-8b",
        family="lm",
        config=FULL,
        smoke_config=SMOKE,
        shapes=dict(LM_SHAPES),
        source="arXiv:2407.21783 (unverified tier)",
        notes="long_500k skipped: pure full attention (DESIGN.md §6).",
    )
)
