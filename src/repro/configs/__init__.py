from repro.configs.base import ArchSpec, get_arch, list_archs  # noqa: F401
