"""qwen1.5-110b [hf:Qwen/Qwen1.5 family]: dense GQA with QKV bias."""

import dataclasses

import jax.numpy as jnp

from repro.configs.base import ArchSpec, LM_SHAPES, register
from repro.models.transformer import LMConfig

FULL = LMConfig(
    name="qwen1.5-110b",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=49152,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    attn_chunk=512,
    dtype=jnp.bfloat16,
)

SMOKE = dataclasses.replace(
    FULL, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=512, attn_chunk=16, dtype=jnp.float32, remat=False,
)

register(
    ArchSpec(
        arch_id="qwen1.5-110b",
        family="lm",
        config=FULL,
        smoke_config=SMOKE,
        shapes=dict(LM_SHAPES),
        source="hf:Qwen/Qwen1.5-0.5B scaled per assignment (hf tier)",
        notes="QKV bias enabled; long_500k skipped (full attention).",
    )
)
