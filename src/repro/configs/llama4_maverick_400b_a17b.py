"""llama4-maverick-400b-a17b [hf:meta-llama/Llama-4]: MoE 128e top-1.

Assignment config: 48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048,
MoE 128 experts top-1 (Switch-style routing).  The released model's early-
fusion multimodal frontend is a stub per the assignment (text backbone
only); all layers MoE (the release interleaves dense/MoE — noted).
"""

import dataclasses

import jax.numpy as jnp

from repro.configs.base import ArchSpec, LM_SHAPES, register
from repro.models.transformer import LMConfig

FULL = LMConfig(
    name="llama4-maverick-400b-a17b",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    d_ff=0,
    vocab=202048,
    moe=True,
    n_experts=128,
    top_k=1,
    d_ff_expert=8192,
    capacity_factor=1.25,
    attn_chunk=512,
    dtype=jnp.bfloat16,
)

SMOKE = dataclasses.replace(
    FULL, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    n_experts=4, top_k=1, d_ff_expert=64, vocab=512, attn_chunk=16,
    dtype=jnp.float32, remat=False,
)

register(
    ArchSpec(
        arch_id="llama4-maverick-400b-a17b",
        family="lm",
        config=FULL,
        smoke_config=SMOKE,
        shapes=dict(LM_SHAPES),
        source="hf:meta-llama/Llama-4-Scout-17B-16E (unverified tier)",
        notes=(
            "modality frontend stubbed (text backbone only); top-1 routing; "
            "long_500k skipped (full attention)."
        ),
    )
)
