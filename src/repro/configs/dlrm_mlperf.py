"""dlrm-mlperf [arXiv:1906.00091]: MLPerf DLRM benchmark config (Criteo 1TB).

Embedding tables use the canonical Criteo-1TB per-field cardinalities
(~188M rows x 128 dims = 96 GB fp32) — row-sharded over the "model" mesh
axis in the dry-run.
"""

import dataclasses

from repro.configs.base import ArchSpec, RECSYS_SHAPES, register
from repro.models.recsys.models import RecConfig

# Canonical MLPerf/Criteo-1TB cardinalities (26 sparse features)
CRITEO_1TB_VOCABS = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36,
)

FULL = RecConfig(
    name="dlrm-mlperf",
    kind="dlrm",
    n_dense=13,
    vocab_sizes=CRITEO_1TB_VOCABS,
    embed_dim=128,
    bot_mlp=(512, 256, 128),
    top_mlp=(1024, 1024, 512, 256, 1),
)

SMOKE = dataclasses.replace(
    FULL, vocab_sizes=(64,) * 26, embed_dim=8, bot_mlp=(16, 8),
    top_mlp=(32, 16, 1),
)

register(
    ArchSpec(
        arch_id="dlrm-mlperf",
        family="recsys",
        config=FULL,
        smoke_config=SMOKE,
        shapes=dict(RECSYS_SHAPES),
        source="arXiv:1906.00091 (paper tier); MLPerf Criteo-1TB vocab",
        notes="paper ANNS technique applies to retrieval_cand (IVF corpus).",
    )
)
