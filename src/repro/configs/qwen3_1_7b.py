"""qwen3-1.7b [hf:Qwen/Qwen3-8B family]: GQA with qk_norm, 152k vocab."""

import dataclasses

import jax.numpy as jnp

from repro.configs.base import ArchSpec, LM_SHAPES, register
from repro.models.transformer import LMConfig

FULL = LMConfig(
    name="qwen3-1.7b",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_head=128,
    d_ff=6144,
    vocab=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    attn_chunk=512,
    dtype=jnp.bfloat16,
)

SMOKE = dataclasses.replace(
    FULL, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=512, attn_chunk=16, dtype=jnp.float32, remat=False,
)

register(
    ArchSpec(
        arch_id="qwen3-1.7b",
        family="lm",
        config=FULL,
        smoke_config=SMOKE,
        shapes=dict(LM_SHAPES),
        source="hf:Qwen/Qwen3-8B (hf tier)",
        notes="qk_norm enabled; long_500k skipped (full attention).",
    )
)
