"""Crash-consistent online snapshots of the serving index.

A snapshot is one published checkpoint directory (via
``checkpoint.manager.CheckpointManager`` — atomic-rename publish, orphan
sweep, retention GC) holding:

* every ``IVFState`` leaf as written by ``core.ivf.state_to_host`` (bf16
  stored as its uint16 bit pattern), plus the PQ codebooks when the index
  is IVFPQ;
* a manifest carrying the **LSN fence** — the WAL LSN of the last mutation
  applied to the captured state — plus ``next_id``, the state-schema
  version, and per-leaf CRC32s.

``step`` in the checkpoint layout *is* the LSN: ``latest_step()`` finds
the most recent snapshot and recovery replays exactly the WAL records
with ``lsn > manifest["lsn"]``.  The capture itself (device_get under the
runtime's state lock) lives in ``ServingRuntime.snapshot``; this module
is the pure publish/load half, so it is testable without a runtime.

``publish`` checks the ``snapshot_publish`` fault site *before* touching
disk: a crash there must leave the previous snapshot and the whole WAL
intact, which the crash-matrix test asserts.
"""

from __future__ import annotations

import json
import logging
import os
from typing import Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointCorruption, CheckpointManager
from repro.core import pq as pqmod
from repro.core.faults import NO_FAULTS, FaultPlan
from repro.core.ivf import (
    STATE_SCHEMA_VERSION,
    StateSchemaError,
    state_from_host,
)

log = logging.getLogger(__name__)

#: persist-directory layout (file-format constants: existing deployments
#: have data under these names).  Defined here, not in recovery.py, so
#: core.runtime can import them without touching the recovery module —
#: recovery imports core.ivf, and a module-level runtime->recovery import
#: would close that cycle on `import repro.persist`.
SNAP_SUBDIR = "snapshots"
WAL_SUBDIR = "wal"

class PersistDirConflict(RuntimeError):
    """A *plain* runtime was pointed at a persist directory that already
    holds snapshots or WAL segments.  Constructing over it would stamp
    the old log's LSNs onto a fresh in-memory index — forking the log
    from the state, and silently shadowing the durable history.  Reopen
    through ``ServingRuntime.recover`` (or use an empty directory)."""


def persist_dir_in_use(root: str) -> bool:
    """True when ``root`` already holds snapshot or WAL data.  Any entry
    under either subtree counts — even orphaned temp dirs mean a prior
    writer whose history a fresh runtime would fork."""
    for sub in (SNAP_SUBDIR, WAL_SUBDIR):
        d = os.path.join(root, sub)
        if os.path.isdir(d) and len(os.listdir(d)) > 0:
            return True
    return False


#: manifest key names (file-format constants: renaming any is a format
#: break for every existing snapshot — treat like WAL_VERSION)
MANIFEST_KIND = "ivf_snapshot"
SNAP_KIND_KEY = "kind"
SNAP_LSN_KEY = "lsn"
SNAP_NEXT_ID_KEY = "next_id"
SNAP_STATE_META_KEY = "state_meta"
SNAP_HAS_PQ_KEY = "has_pq"


def _tree(arrays: "dict[str, np.ndarray]", fields: "list[str]",
          pq_books: Optional[np.ndarray]) -> dict:
    """The exact pytree handed to CheckpointManager: field order comes
    from the state meta (not dict iteration), PQ codebooks ride as an
    extra leaf list so flat and PQ indexes differ only in leaf count."""
    return {
        "pq": [] if pq_books is None else [np.asarray(pq_books)],
        "state": [arrays[name] for name in fields],
    }


def publish(
    mgr: CheckpointManager,
    arrays: "dict[str, np.ndarray]",
    state_meta: dict,
    *,
    lsn: int,
    next_id: int,
    pq_books: Optional[np.ndarray] = None,
    faults: Optional[FaultPlan] = None,
) -> int:
    """Write one snapshot (synchronously — the runtime calls this from its
    own background thread).  Returns the published LSN."""
    plan = faults if faults is not None else NO_FAULTS
    plan.check("snapshot_publish")
    fields = list(state_meta["fields"])
    extra = {
        SNAP_KIND_KEY: MANIFEST_KIND,
        SNAP_LSN_KEY: int(lsn),
        SNAP_NEXT_ID_KEY: int(next_id),
        SNAP_STATE_META_KEY: state_meta,
        SNAP_HAS_PQ_KEY: pq_books is not None,
    }
    mgr.save(int(lsn), _tree(arrays, fields, pq_books), extra=extra)
    log.info("published snapshot @ lsn %d (%d leaves)", lsn, len(fields))
    return int(lsn)


def load_latest(directory: str):
    """Load the newest published snapshot.

    Returns ``(state, pq, manifest)`` — ``state`` a device-resident,
    CRC-verified ``IVFState``; ``pq`` a :class:`PQParams` or ``None``;
    ``manifest`` the dict carrying the LSN fence.  Raises
    ``FileNotFoundError`` when the directory holds no snapshot, and
    :class:`CheckpointCorruption` / ``StateSchemaError`` /
    ``StateChecksumError`` when it holds one that cannot be trusted.
    """
    mgr = CheckpointManager(directory)
    step = mgr.latest_step()
    if step is None:
        raise FileNotFoundError(f"no snapshots in {directory}")
    # peek the manifest first: the `like` template's leaf count depends on
    # whether PQ codebooks were captured
    with open(os.path.join(mgr._step_dir(step), "manifest.json")) as f:
        manifest = json.load(f)
    if manifest.get(SNAP_KIND_KEY) != MANIFEST_KIND:
        raise CheckpointCorruption(
            f"{directory}: step {step} is not an index snapshot "
            f"(kind={manifest.get(SNAP_KIND_KEY)!r})"
        )
    meta = manifest.get(SNAP_STATE_META_KEY)
    if not isinstance(meta, dict) or "fields" not in meta:
        raise CheckpointCorruption(
            f"{directory}: snapshot manifest lacks state_meta"
        )
    if meta.get("schema") != STATE_SCHEMA_VERSION:
        raise StateSchemaError(
            f"snapshot schema {meta.get('schema')!r} != this build's "
            f"{STATE_SCHEMA_VERSION}"
        )
    fields = list(meta["fields"])
    has_pq = bool(manifest.get(SNAP_HAS_PQ_KEY))
    placeholder = np.zeros((1,), np.float32)
    like = {
        "pq": [placeholder] if has_pq else [],
        "state": [placeholder] * len(fields),
    }
    tree, _ = mgr.restore(step=step, like=like)
    arrays = {
        name: np.asarray(jax.device_get(leaf))
        for name, leaf in zip(fields, tree["state"])
    }
    state = state_from_host(arrays, meta, verify=True)
    pq = None
    if has_pq:
        books = np.asarray(jax.device_get(tree["pq"][0]), np.float32)
        pq = pqmod.PQParams(codebooks=jax.numpy.asarray(books))
    return state, pq, manifest
