"""Verified recovery: snapshot + WAL replay -> a serving-ready index.

The contract (docs/serving_ops.md "recovery runbook"):

1. load the newest published snapshot (CRC-verified per leaf, schema
   checked) and its LSN fence;
2. replay exactly the WAL records with ``lsn > fence``, in LSN order,
   through the *same* jitted batch steps the online lane dispatches
   (``core.mutate.make_replay_fns``) — a torn/CRC-failing tail is
   truncated loudly and counted, any other damage refuses recovery;
3. verify before accepting traffic: ``check_invariants`` over the full
   pool plus a sampled id_map <-> pool_live cross-check (both directions).

Every refusal raises :class:`RecoveryError` with the cause chained — a
node that cannot prove its recovered state is exactly the acked history
must not serve approximate answers from it.  Recovery itself never writes
to the persist directory (WAL tail repair happens later, when the runtime
re-opens the log), so a crash mid-replay — injectable at the
``recovery_replay`` site — is re-recoverable from the same bytes.
"""

from __future__ import annotations

import dataclasses
import logging
import os
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import pq as pqmod
from repro.core.block_pool import NULL, check_invariants
from repro.core.faults import NO_FAULTS, FaultPlan
from repro.core.ivf import IVFIndex, IVFIndexConfig
from repro.core.mutate import make_replay_fns
from repro.persist import snapshot as snapmod
from repro.persist.snapshot import SNAP_SUBDIR, WAL_SUBDIR
from repro.persist.wal import read_wal

log = logging.getLogger(__name__)


class RecoveryError(RuntimeError):
    """Recovery could not prove the restored state matches the acked
    history — the node must refuse to serve, not guess.

    When raised by :func:`recover_index`, carries the partially-filled
    :class:`RecoveryReport` as ``report`` (how far recovery got)."""

    report: "Optional[RecoveryReport]" = None


@dataclasses.dataclass
class RecoveryReport:
    """What recovery did, for operators and the property tests."""

    snapshot_lsn: int = 0
    replayed_records: int = 0
    replayed_rows: int = 0
    last_lsn: int = 0
    next_id: int = 0
    wal_segments: int = 0
    torn_tail: int = 0
    torn_detail: Optional[str] = None
    sampled_ids_checked: int = 0
    sampled_slots_checked: int = 0
    verified: bool = False

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def _pow2_bucket(n: int) -> int:
    b = 1
    while b < n:
        b <<= 1
    return b


def _pad_batch(ids: np.ndarray, vectors: Optional[np.ndarray], dim: int):
    """Pad a replayed batch to its power-of-two bucket — the same bucket
    discipline the serving runtime uses, so replay reuses (or warms) the
    very jit caches online traffic hits."""
    n = len(ids)
    b = _pow2_bucket(n)
    pid = np.full((b,), NULL, np.int32)
    pid[:n] = ids
    valid = np.zeros((b,), bool)
    valid[:n] = True
    if vectors is None:
        vec = jnp.zeros((b, dim), jnp.float32)
    else:
        pv = np.zeros((b, dim), np.float32)
        pv[:n] = vectors
        vec = jnp.asarray(pv)
    return jnp.asarray(pid), vec, jnp.asarray(valid)


def verify_index(index: IVFIndex, report: RecoveryReport,
                 sample: int = 256, seed: int = 0) -> None:
    """Invariant sweep + sampled cross-check; raises RecoveryError.

    ``check_invariants`` walks every chain (structure, lengths, free-stack
    disjointness).  The sampled pass cross-checks the two residency maps
    against each other: a forward pass (id_map entry -> slot must be live
    and hold that id) and a reverse pass (live slot -> its id must map
    back to it).  A snapshot/replay divergence that kept both structures
    self-consistent but *disagreeing* — e.g. a replayed delete lost on one
    side — is exactly what this catches."""
    state, cfg = index.state, index.pool_cfg
    try:
        check_invariants(state, cfg)
    except AssertionError as e:
        raise RecoveryError(
            f"recovered state failed check_invariants: {e}"
        ) from e
    host_map = np.asarray(state.id_map)
    host_live = np.asarray(state.pool_live)
    host_ids = np.asarray(state.pool_ids)
    tm = cfg.block_size
    rng = np.random.default_rng(seed)

    mapped = np.flatnonzero(host_map != NULL)
    if len(mapped) > sample:
        mapped = rng.choice(mapped, size=sample, replace=False)
    for vid in mapped:
        loc = int(host_map[vid])
        blk, off = divmod(loc, tm)
        if not host_live[blk, off]:
            raise RecoveryError(
                f"id_map[{int(vid)}] -> slot {loc}, but pool_live says the "
                "slot is dead — id map and tombstone mask diverged"
            )
        if int(host_ids[blk, off]) != int(vid):
            raise RecoveryError(
                f"id_map[{int(vid)}] -> slot {loc}, but the slot holds id "
                f"{int(host_ids[blk, off])} — id map points at a stolen slot"
            )
    report.sampled_ids_checked = int(len(mapped))

    live_slots = np.flatnonzero(host_live.ravel())
    if len(live_slots) > sample:
        live_slots = rng.choice(live_slots, size=sample, replace=False)
    for loc in live_slots:
        blk, off = divmod(int(loc), tm)
        vid = int(host_ids[blk, off])
        if vid == NULL:
            raise RecoveryError(
                f"slot {int(loc)} is live but holds NULL id"
            )
        if vid >= len(host_map) or int(host_map[vid]) != int(loc):
            raise RecoveryError(
                f"slot {int(loc)} holds id {vid} but id_map[{vid}] = "
                f"{int(host_map[vid]) if vid < len(host_map) else 'out-of-range'}"
                " — a live row is unreachable by id"
            )
    report.sampled_slots_checked = int(len(live_slots))
    report.verified = True


def recover_index(
    cfg: IVFIndexConfig,
    persist_dir: str,
    *,
    faults: Optional[FaultPlan] = None,
    sample: int = 256,
) -> "tuple[IVFIndex, RecoveryReport]":
    """The whole recovery pipeline; the only way back from a crash.

    Returns a verified, serving-ready :class:`IVFIndex` plus the report.
    Raises :class:`RecoveryError` (cause chained) on anything it cannot
    prove — missing snapshot, schema/CRC failure, mid-log corruption, LSN
    gap, replay failure, invariant violation.  The raised error carries
    the partially-filled report as ``e.report`` — how far recovery got
    before it refused — which the runtime's recovery-failure debug bundle
    (``repro.obs.bundle``) persists for the post-mortem.
    """
    plan = faults if faults is not None else NO_FAULTS
    report = RecoveryReport()
    try:
        return _recover_index(cfg, persist_dir, plan, report, sample)
    except RecoveryError as e:
        e.report = report
        raise


def _recover_index(
    cfg: IVFIndexConfig,
    persist_dir: str,
    plan: FaultPlan,
    report: RecoveryReport,
    sample: int,
) -> "tuple[IVFIndex, RecoveryReport]":
    snap_dir = os.path.join(persist_dir, SNAP_SUBDIR)
    wal_dir = os.path.join(persist_dir, WAL_SUBDIR)

    try:
        state, pq, manifest = snapmod.load_latest(snap_dir)
    except Exception as e:
        raise RecoveryError(f"cannot load a snapshot: {e}") from e
    snap_lsn = int(manifest[snapmod.SNAP_LSN_KEY])
    next_id = int(manifest[snapmod.SNAP_NEXT_ID_KEY])
    report.snapshot_lsn = report.last_lsn = snap_lsn

    try:
        records, wal_report = read_wal(wal_dir, min_lsn=snap_lsn)
    except Exception as e:
        raise RecoveryError(f"WAL unreadable past lsn {snap_lsn}: {e}") from e
    report.wal_segments = wal_report["segments"]
    report.torn_tail = wal_report["torn_tail"]
    report.torn_detail = wal_report["torn_detail"]
    if records and records[0].lsn != snap_lsn + 1:
        raise RecoveryError(
            f"WAL starts at lsn {records[0].lsn} but the snapshot fence is "
            f"{snap_lsn} — records {snap_lsn + 1}..{records[0].lsn - 1} "
            "were pruned without a covering snapshot"
        )

    index = IVFIndex(cfg)
    try:
        index.install_state(state, pq=pq, next_id=next_id)
    except Exception as e:
        raise RecoveryError(f"snapshot does not fit this config: {e}") from e

    encode = pqmod.make_pq_encode_fn(pq) if pq is not None else None
    replay = make_replay_fns(index.pool_cfg, encode=encode)
    dim = index.pool_cfg.dim
    cur = index.state
    max_id = next_id - 1
    try:
        for rec in records:
            plan.check("recovery_replay")
            ids, vec, valid = _pad_batch(rec.ids, rec.vectors, dim)
            cur = replay[rec.kind](cur, vec, ids, valid)
            report.replayed_records += 1
            report.replayed_rows += rec.rows
            report.last_lsn = rec.lsn
            if rec.kind != "delete" and rec.rows:
                max_id = max(max_id, int(rec.ids.max()))
    except Exception as e:
        raise RecoveryError(
            f"replay failed at lsn {report.last_lsn + 1}: {e}"
        ) from e
    index.state = cur
    # replayed inserts minted ids past the snapshot's allocator cursor
    index._next_id = max_id + 1
    report.next_id = max_id + 1

    verify_index(index, report, sample=sample, seed=cfg.seed)
    log.info(
        "recovered: snapshot lsn %d + %d replayed records (%d rows), "
        "verified", snap_lsn, report.replayed_records, report.replayed_rows,
    )
    return index, report
