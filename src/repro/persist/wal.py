"""Mutation write-ahead log: the durability floor of the online index.

Every admitted insert/delete/update batch is appended here *before* the
device apply (see ``ServingRuntime._apply_run``), so a ``kill -9`` at any
instant loses at most work that was never acknowledged.  Acks happen after
the apply, which happens after the append — with the default
``sync_interval=1`` (fsync per appended batch) the acked set is always a
subset of the durable set: **RPO = 0 acked rows**.  Larger intervals batch
the fsync across appends and trade that guarantee for throughput (up to
``interval - 1`` most-recent batches may be acked-but-volatile; see
docs/serving_ops.md "fsync interval tradeoff").

On-disk layout: a directory of segment files ``wal_<seq>.log``.  Each
segment is an 8-byte header (magic + format version) followed by
length-prefixed records:

    u32 payload_len | u32 crc32 | u64 lsn | u8 kind | 3x pad | payload

The CRC32 covers everything after itself (lsn, kind, pad, payload), so a
torn tail — the page cache's half-written last record after power loss —
fails loudly instead of replaying garbage.  LSNs are assigned by
``append`` and are strictly monotonically increasing across segments;
``rotate()`` (called by the snapshot barrier) seals the active segment so
``prune(lsn)`` can drop whole segments once a published snapshot covers
them — the WAL is truncated only *after* the snapshot publish succeeds.

Record payloads are raw little-endian arrays (ids i32, vectors f32), not
pickles: replay of a hostile or corrupt log can fail a CRC, never execute
code.  A batch may fail *between* append and apply (injected fault, device
error): its record still replays on recovery.  That is at-least-once
delivery of never-acked work — inserts mint fresh ids per submit, deletes
are idempotent, updates are last-write-wins, so replaying it is always
safe.

A failed *append* (transient ENOSPC/EIO, failed fsync) is rolled back:
the segment is truncated to the end of its last good record before the
error propagates, so a retry's re-append can never collide with the dead
record's bytes (duplicate rows, or garbage that a later scan reads as
mid-log corruption).  If even the rollback fails, the log fails closed —
:class:`WALUnavailable` on every further append — instead of writing
past an untrusted tail.
"""

from __future__ import annotations

import dataclasses
import logging
import os
import struct
import threading
import zlib
from typing import Iterator, Optional

import numpy as np

from repro.core.faults import NO_FAULTS, FaultPlan
from repro.obs.events import EV_WAL_FSYNC, EV_WAL_ROTATE

log = logging.getLogger(__name__)

# ---- file-format constants (cache-key-relevant config: changing any of
# ---- these is a format break — bump WAL_VERSION and teach replay; the
# ---- persist-format lint rule keeps them named, never inline) -----------
WAL_MAGIC = b"RWAL"
WAL_VERSION = 1
SEG_HEADER_FMT = "<4sHH"  # magic, version, reserved
SEG_HEADER_LEN = struct.calcsize(SEG_HEADER_FMT)  # 8
REC_LEN_CRC_FMT = "<II"  # payload_len, crc32 (not covered by the crc)
REC_LEN_CRC_LEN = struct.calcsize(REC_LEN_CRC_FMT)  # 8
REC_TAIL_FMT = "<QB3x"  # lsn, kind, pad (crc-covered, with the payload)
REC_HEADER_FMT = "<IIQB3x"  # the two of those, as read back in one go
REC_HEADER_LEN = struct.calcsize(REC_HEADER_FMT)  # 20
PAYLOAD_HEADER_FMT = "<II"  # n_rows, dim (0 for delete)
PAYLOAD_HEADER_LEN = struct.calcsize(PAYLOAD_HEADER_FMT)  # 8
#: kind byte <-> mutation kind (order matches core.mutate.REPLAY_KINDS)
KIND_CODES = {"insert": 0, "delete": 1, "update": 2}
KIND_NAMES = {v: k for k, v in KIND_CODES.items()}
_SEG_PREFIX = "wal_"
_SEG_SUFFIX = ".log"


class WALCorruption(RuntimeError):
    """A WAL segment failed validation somewhere other than its tail —
    unlike a torn tail (a normal crash artifact, truncated loudly), this
    means lost or mangled history and recovery must refuse to serve."""


class WALUnavailable(RuntimeError):
    """The append side failed closed: a failed append could not be rolled
    back, so the active segment's tail is untrusted.  Writing past it
    would bury garbage mid-log — unrecoverable corruption instead of a
    truncatable tail — so every further append/rotate raises this until
    the log is re-opened (which repairs the tail by CRC)."""


@dataclasses.dataclass(frozen=True)
class WALRecord:
    """One durably logged mutation batch."""

    lsn: int
    kind: str  # insert | delete | update
    ids: np.ndarray  # [n] i32
    vectors: Optional[np.ndarray]  # [n, d] f32 (insert/update) | None
    nbytes: int = 0  # on-disk size incl. record header (tail repair)

    @property
    def rows(self) -> int:
        return len(self.ids)


def encode_record(lsn: int, kind: str, ids: np.ndarray,
                  vectors: Optional[np.ndarray]) -> bytes:
    ids = np.ascontiguousarray(ids, dtype="<i4")
    n = len(ids)
    if kind == "delete":
        if vectors is not None:
            raise ValueError("delete records carry no vectors")
        body = struct.pack(PAYLOAD_HEADER_FMT, n, 0) + ids.tobytes()
    else:
        vectors = np.ascontiguousarray(vectors, dtype="<f4")
        if vectors.ndim != 2 or len(vectors) != n:
            raise ValueError(f"{kind}: {n} ids for vectors {vectors.shape}")
        body = (
            struct.pack(PAYLOAD_HEADER_FMT, n, vectors.shape[1])
            + ids.tobytes()
            + vectors.tobytes()
        )
    tail = struct.pack(REC_TAIL_FMT, lsn, KIND_CODES[kind]) + body
    crc = zlib.crc32(tail)
    return struct.pack(REC_LEN_CRC_FMT, len(body), crc) + tail


def _decode_payload(
    kind: str, body: bytes
) -> "tuple[np.ndarray, Optional[np.ndarray]]":
    n, dim = struct.unpack_from(PAYLOAD_HEADER_FMT, body, 0)
    off = PAYLOAD_HEADER_LEN
    ids = np.frombuffer(body, dtype="<i4", count=n, offset=off).astype(
        np.int32
    )
    if kind == "delete":
        return ids, None
    off += ids.itemsize * n
    vec = np.frombuffer(body, dtype="<f4", count=n * dim, offset=off)
    return ids, vec.reshape(n, dim).astype(np.float32)


def iter_segment(path: str) -> "Iterator[WALRecord | str]":
    """Yield records of one segment; on a torn/corrupt record, yield one
    final ``str`` describing the damage and stop (the caller decides
    whether that is a legal crash tail or corruption)."""
    with open(path, "rb") as f:
        head = f.read(SEG_HEADER_LEN)
        if len(head) < SEG_HEADER_LEN:
            yield f"{path}: short segment header"
            return
        magic, version, _ = struct.unpack(SEG_HEADER_FMT, head)
        if magic != WAL_MAGIC:
            yield f"{path}: bad magic {magic!r}"
            return
        if version != WAL_VERSION:
            yield f"{path}: WAL format version {version} != {WAL_VERSION}"
            return
        while True:
            hdr = f.read(REC_HEADER_LEN)
            if not hdr:
                return  # clean end
            if len(hdr) < REC_HEADER_LEN:
                yield f"{path}: torn record header ({len(hdr)} bytes)"
                return
            body_len, crc, lsn, kind_code = struct.unpack(
                REC_HEADER_FMT, hdr
            )
            body = f.read(body_len)
            if len(body) < body_len:
                yield (f"{path}: torn record body @ lsn {lsn} "
                       f"({len(body)}/{body_len} bytes)")
                return
            if zlib.crc32(hdr[REC_LEN_CRC_LEN:] + body) != crc:
                yield f"{path}: CRC mismatch @ lsn {lsn}"
                return
            kind = KIND_NAMES.get(kind_code)
            if kind is None:
                yield f"{path}: unknown record kind {kind_code} @ lsn {lsn}"
                return
            ids, vectors = _decode_payload(kind, body)
            yield WALRecord(
                lsn=lsn, kind=kind, ids=ids, vectors=vectors,
                nbytes=REC_HEADER_LEN + body_len,
            )


def _segment_paths(directory: str) -> "list[str]":
    if not os.path.isdir(directory):
        return []
    names = sorted(
        d for d in os.listdir(directory)
        if d.startswith(_SEG_PREFIX) and d.endswith(_SEG_SUFFIX)
    )
    return [os.path.join(directory, d) for d in names]


def read_wal(
    directory: str, min_lsn: int = 0
) -> "tuple[list[WALRecord], dict]":
    """Scan every segment in order; return the records with
    ``lsn > min_lsn`` plus a report dict.

    A torn/CRC-failing record is a legal crash artifact only at the very
    tail of the *last* segment: there it is truncated loudly (logged,
    counted in ``report['torn_tail']``).  Anywhere else it means lost
    history — :class:`WALCorruption`.
    """
    paths = _segment_paths(directory)
    records: list[WALRecord] = []
    report = {
        "segments": len(paths),
        "scanned_records": 0,
        "torn_tail": 0,
        "torn_detail": None,
    }
    for i, path in enumerate(paths):
        for item in iter_segment(path):
            if isinstance(item, str):
                if i != len(paths) - 1:
                    raise WALCorruption(
                        f"damage in a non-final segment: {item}"
                    )
                log.warning("WAL tail truncated: %s", item)
                report["torn_tail"] += 1
                report["torn_detail"] = item
                break
            report["scanned_records"] += 1
            if item.lsn > min_lsn:
                records.append(item)
    for a, b in zip(records, records[1:]):
        if b.lsn != a.lsn + 1:
            raise WALCorruption(
                f"LSN gap in WAL: {a.lsn} -> {b.lsn} (records lost)"
            )
    return records, report


class MutationWAL:
    """Append-side handle.  Thread-safe; one writer process per directory.

    ``sync_interval`` counts *appends* between fsyncs (1 = every batch).
    ``append`` and ``sync`` check the ``wal_append`` / ``wal_fsync`` fault
    sites so tests can crash the process model at either point.
    """

    def __init__(self, directory: str, sync_interval: int = 1,
                 faults: Optional[FaultPlan] = None, start_lsn: int = 0,
                 recorder=None):
        """``start_lsn`` is the LSN floor — the owning runtime passes its
        latest snapshot fence.  Without it, reopening a log whose segments
        were all pruned (fence == last LSN) would restart numbering at 1
        and the new records would collide with — and be filtered out
        below — the fence: silent loss of everything after the reopen."""
        if sync_interval < 1:
            raise ValueError(f"sync_interval must be >= 1: {sync_interval}")
        self.dir = directory
        self.sync_interval = sync_interval
        self._faults = faults if faults is not None else NO_FAULTS
        # optional flight recorder (repro.obs.events.FlightRecorder): the
        # owning runtime passes its own so fsync/rotate land on the same
        # timeline as the control-plane transitions.  record_event takes
        # only the recorder's leaf lock, so calling it under _lock is safe.
        self._recorder = recorder
        os.makedirs(directory, exist_ok=True)
        self._lock = threading.Lock()
        self._file = None  # guarded-by: _lock
        self._path = ""  # guarded-by: _lock
        self._sealed: list = []  # guarded-by: _lock — (path, last_lsn)
        self._seq = 0  # guarded-by: _lock
        self._seg_count = 0  # guarded-by: _lock — records in active segment
        self._last_lsn = 0  # guarded-by: _lock
        self._durable_lsn = 0  # guarded-by: _lock
        self._unsynced = 0  # guarded-by: _lock
        self._failed = False  # guarded-by: _lock — rollback failed: closed
        with self._lock:
            self._adopt_existing()
            self._last_lsn = max(self._last_lsn, int(start_lsn))
            self._durable_lsn = self._last_lsn
            self._open_segment()

    # ------------------------------------------------------------ open ---
    def _adopt_existing(self):  # holds: _lock
        """Continue LSNs after the existing log (recovery hand-off).  A
        torn tail in the last segment is *repaired* here — truncated to
        the end of its last good record — so later scans never mistake
        the healed crash artifact for mid-log corruption."""
        paths = _segment_paths(self.dir)
        for i, path in enumerate(paths):
            last, good_bytes, damage = 0, SEG_HEADER_LEN, None
            for item in iter_segment(path):
                if isinstance(item, str):
                    damage = item
                    break
                last = item.lsn
                good_bytes += item.nbytes
            if damage is not None:
                if i != len(paths) - 1:
                    raise WALCorruption(
                        f"damage in a non-final segment: {damage}"
                    )
                log.warning(
                    "repairing torn WAL tail (%s): truncating %s to %d "
                    "bytes", damage, path, good_bytes,
                )
                with open(path, "r+b") as f:
                    f.truncate(good_bytes)
            name = os.path.basename(path)
            self._seq = max(
                self._seq,
                int(name[len(_SEG_PREFIX): -len(_SEG_SUFFIX)]),
            )
            if last:
                self._sealed.append((path, last))
                self._last_lsn = max(self._last_lsn, last)
            else:
                os.remove(path)  # held no intact record: drop it
        self._durable_lsn = self._last_lsn

    def _open_segment(self):  # holds: _lock
        self._seq += 1
        path = os.path.join(
            self.dir, f"{_SEG_PREFIX}{self._seq:010d}{_SEG_SUFFIX}"
        )
        self._file = open(path, "xb")
        self._file.write(
            struct.pack(SEG_HEADER_FMT, WAL_MAGIC, WAL_VERSION, 0)
        )
        self._file.flush()
        self._path = path
        self._seg_count = 0

    # ---------------------------------------------------------- append ---
    def append(self, kind: str, ids: np.ndarray,
               vectors: Optional[np.ndarray] = None) -> int:
        """Durably stage one mutation batch; returns its LSN.  Raises if
        the write or a due fsync fails — the caller must then *not* apply
        the batch.  A failed append is *rolled back*: the segment is
        truncated to the end of its last good record and the LSN counter
        restored, so the dead record's bytes cannot linger and collide
        with the retry's re-append (duplicate rows, or mid-log garbage
        that recovery cannot distinguish from lost history).  If even the
        rollback fails, the log fails closed (:class:`WALUnavailable` on
        every later append) instead of writing past an untrusted tail."""
        self._faults.check("wal_append")
        with self._lock:
            self._ensure_open()
            saved = (self._last_lsn, self._seg_count, self._unsynced)
            pos = self._file.tell()
            lsn = self._last_lsn + 1
            try:
                self._file.write(encode_record(lsn, kind, ids, vectors))
                self._last_lsn = lsn
                self._seg_count += 1
                self._unsynced += 1
                if self._unsynced >= self.sync_interval:
                    self._sync_locked()
                else:
                    self._file.flush()  # page cache; fsync is batched
            except Exception:
                self._last_lsn, self._seg_count, self._unsynced = saved
                self._rollback_locked(pos)
                raise
        return lsn

    def _ensure_open(self):  # holds: _lock
        if self._failed:
            raise WALUnavailable(
                f"{self._path}: a failed append could not be rolled back; "
                "refusing to write past an untrusted tail (re-open the "
                "log to repair it)"
            )

    def _rollback_locked(self, pos: int):  # holds: _lock
        """Truncate the active segment back to ``pos`` (the end of its
        last good record) after a failed append.  The seek flushes any
        half-buffered bytes first; the truncate then cuts them and the
        failed record together.  Failure here fails the log closed —
        see ``append``."""
        try:
            self._file.seek(pos)
            self._file.truncate(pos)
            self._file.flush()
        except Exception:
            self._failed = True
            log.exception(
                "WAL rollback to offset %d of %s failed; the log is "
                "failing closed", pos, self._path,
            )

    def _sync_locked(self):  # holds: _lock
        self._faults.check("wal_fsync")
        self._file.flush()
        os.fsync(self._file.fileno())
        self._durable_lsn = self._last_lsn
        self._unsynced = 0
        if self._recorder is not None:
            self._recorder.record_event(
                EV_WAL_FSYNC, durable_lsn=self._durable_lsn
            )

    def sync(self) -> int:
        """Force an fsync now; returns the durable LSN."""
        with self._lock:
            if self._unsynced:
                self._sync_locked()
            return self._durable_lsn

    # ----------------------------------------------------- snapshotting --
    def rotate(self) -> int:
        """Seal the active segment and start a fresh one (the snapshot
        barrier calls this so ``prune`` can later drop whole sealed
        segments).  Returns the WAL's last LSN."""
        with self._lock:
            self._ensure_open()  # sealing an untrusted tail buries garbage
            if self._unsynced:
                self._sync_locked()
            self._file.close()
            if self._seg_count:
                self._sealed.append((self._path, self._last_lsn))
            else:
                os.remove(self._path)  # never held a record
            self._open_segment()
            if self._recorder is not None:
                self._recorder.record_event(
                    EV_WAL_ROTATE, last_lsn=self._last_lsn,
                    sealed_segments=len(self._sealed),
                )
            return self._last_lsn

    def prune(self, upto_lsn: int) -> int:
        """Delete sealed segments whose every record has
        ``lsn <= upto_lsn`` (i.e. is covered by a *published* snapshot).
        Only call after the publish succeeded.  Returns #segments dropped."""
        with self._lock:
            keep, dropped = [], 0
            for path, last in self._sealed:
                if last <= upto_lsn:
                    os.remove(path)
                    dropped += 1
                else:
                    keep.append((path, last))
            self._sealed = keep
            return dropped

    # ------------------------------------------------------------ state --
    @property
    def last_lsn(self) -> int:
        with self._lock:
            return self._last_lsn

    @property
    def durable_lsn(self) -> int:
        with self._lock:
            return self._durable_lsn

    def lsns(self) -> "tuple[int, int]":
        """``(last_lsn, durable_lsn)`` as ONE consistent read.  Reading
        the two properties back-to-back takes the lock twice; an append +
        fsync landing between them yields a pair (stale last, fresh
        durable) where ``durable > last`` — nonsense under the LSN
        contract.  ``stats()`` reads through here."""
        with self._lock:
            return self._last_lsn, self._durable_lsn

    def close(self):
        with self._lock:
            if self._file is not None and not self._file.closed:
                if self._unsynced:
                    try:
                        self._sync_locked()
                    except Exception:  # close must not mask a shutdown path
                        log.exception("WAL final fsync failed")
                self._file.close()
