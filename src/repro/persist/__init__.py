"""Durability for the online index: mutation WAL, crash-consistent
snapshots, verified recovery.  See docs/serving_ops.md "Durability"."""

from repro.persist.recovery import (
    SNAP_SUBDIR,
    WAL_SUBDIR,
    RecoveryError,
    RecoveryReport,
    recover_index,
    verify_index,
)
from repro.persist.snapshot import (
    PersistDirConflict,
    load_latest,
    persist_dir_in_use,
    publish,
)
from repro.persist.wal import (
    MutationWAL,
    WALCorruption,
    WALRecord,
    WALUnavailable,
    read_wal,
)

__all__ = [
    "SNAP_SUBDIR",
    "WAL_SUBDIR",
    "PersistDirConflict",
    "RecoveryError",
    "RecoveryReport",
    "recover_index",
    "verify_index",
    "load_latest",
    "persist_dir_in_use",
    "publish",
    "MutationWAL",
    "WALCorruption",
    "WALRecord",
    "WALUnavailable",
    "read_wal",
]
