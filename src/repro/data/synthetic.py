"""Deterministic synthetic data streams for every architecture family.

The container is offline; every benchmark/experiment draws from these
generators.  They are shaped to match the public datasets they stand in for
(SIFT1M 128-d, the paper's DSSM 64-d corpus, Criteo click logs, OGB graphs)
and are seeded so restarts replay identically (the fault-tolerance story
depends on a deterministic data cursor).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


def sift_like(n: int, dim: int = 128, seed: int = 0, n_modes: int = 64):
    """Clustered float vectors resembling SIFT descriptors (non-negative)."""
    rng = np.random.default_rng(seed)
    centers = rng.gamma(2.0, 20.0, size=(n_modes, dim)).astype(np.float32)
    assign = rng.integers(0, n_modes, n)
    x = centers[assign] + rng.normal(0, 8.0, size=(n, dim)).astype(np.float32)
    return np.maximum(x, 0.0).astype(np.float32)


def dssm_like(n: int, dim: int = 64, seed: int = 1, n_topics: int = 256):
    """Normalised embedding-model vectors (the paper's industrial corpus)."""
    rng = np.random.default_rng(seed)
    topics = rng.normal(size=(n_topics, dim)).astype(np.float32)
    assign = rng.integers(0, n_topics, n)
    x = topics[assign] + 0.3 * rng.normal(size=(n, dim)).astype(np.float32)
    return (x / np.linalg.norm(x, axis=1, keepdims=True)).astype(np.float32)


def token_stream(
    batch: int, seq: int, vocab: int, seed: int = 0, start_step: int = 0
) -> Iterator[dict]:
    """Zipf-distributed token batches; cursor = step (restart-replayable)."""
    step = start_step
    while True:
        rng = np.random.default_rng((seed, step))
        toks = rng.zipf(1.3, size=(batch, seq + 1)) % vocab
        yield {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
            "step": step,
        }
        step += 1


def click_stream(
    batch: int,
    n_dense: int,
    vocab_sizes,
    seed: int = 0,
    seq_len: int = 0,
    start_step: int = 0,
) -> Iterator[dict]:
    """Criteo-like click logs: lognormal dense + Zipf categorical ids."""
    vocab_sizes = np.asarray(vocab_sizes)
    step = start_step
    while True:
        rng = np.random.default_rng((seed, step))
        dense = rng.lognormal(0, 1, size=(batch, n_dense)).astype(np.float32)
        sparse = (rng.zipf(1.2, size=(batch, len(vocab_sizes))) - 1) % vocab_sizes
        out = {
            "dense": np.log1p(dense),
            "sparse": sparse.astype(np.int32),
            "label": (rng.random(batch) < 0.25).astype(np.float32),
            "step": step,
        }
        if seq_len:
            out["history"] = (
                (rng.zipf(1.2, size=(batch, seq_len)) - 1) % vocab_sizes[0]
            ).astype(np.int32)
        yield out
        step += 1


def random_graph(
    n_nodes: int, avg_degree: int, d_feat: int, seed: int = 0, n_classes: int = 16
):
    """Power-law-ish random graph with 3D positions + features."""
    rng = np.random.default_rng(seed)
    n_edges = n_nodes * avg_degree
    # preferential-attachment flavour: quadratic skew toward low ids
    src = (rng.random(n_edges) ** 2 * n_nodes).astype(np.int64)
    dst = rng.integers(0, n_nodes, n_edges)
    keep = src != dst  # no self loops (degenerate eSCN frames)
    return {
        "edge_src": src[keep].astype(np.int32),
        "edge_dst": dst[keep].astype(np.int32),
        "node_feat": rng.normal(size=(n_nodes, d_feat)).astype(np.float32),
        "pos": rng.normal(size=(n_nodes, 3)).astype(np.float32),
        "label": rng.integers(0, n_classes, n_nodes).astype(np.int32),
    }


def molecule_batch(n_mols: int, nodes_per_mol: int, edges_per_mol: int, seed=0):
    """Batched small molecules (the ``molecule`` shape): graph regression."""
    rng = np.random.default_rng(seed)
    n = n_mols * nodes_per_mol
    pos = rng.normal(size=(n, 3)).astype(np.float32) * 2.0
    feat = rng.normal(size=(n, 16)).astype(np.float32)
    srcs, dsts = [], []
    for m in range(n_mols):
        base = m * nodes_per_mol
        s = rng.integers(0, nodes_per_mol, edges_per_mol)
        d = (s + 1 + rng.integers(0, nodes_per_mol - 1, edges_per_mol)) % nodes_per_mol
        srcs.append(base + s)
        dsts.append(base + d)
    graph_ids = np.repeat(np.arange(n_mols), nodes_per_mol)
    return {
        "node_feat": feat,
        "pos": pos,
        "edge_src": np.concatenate(srcs).astype(np.int32),
        "edge_dst": np.concatenate(dsts).astype(np.int32),
        "graph_ids": graph_ids.astype(np.int32),
        "n_graphs": n_mols,
        "target": rng.normal(size=(n_mols,)).astype(np.float32),
    }
